"""Dense decoder-only transformer LM (llama/qwen/stablelm-style, GQA).

Covers the assigned archs tinyllama-1.1b, qwen1.5-{0.5b,4b} (QKV bias),
stablelm-1.6b (partial RoPE, LayerNorm), and the internvl2-76b LM backbone
(``frontend="vision"``: precomputed patch embeddings are prepended to the
token embeddings; the ViT itself is a stub per the assignment).

Layers are weight-stacked and executed with ``lax.scan``; caches carry a
leading layer dim and ride along as scan xs/ys.

``batch`` dict keys:
  train : tokens (B,S) int32, labels (B,S) int32 (-1 = masked),
          [prefix_embeds (B,P,D) for vlm]
  prefill: tokens (B,S), [prefix_embeds]
  decode : tokens (B,1)
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .api import ModelConfig
from .attention import (
    KVCache,
    attend,
    kv_cache_abstract,
    kv_cache_init,
    kv_cache_layer_update,
    kv_cache_slot_positions,
)
from .common import (
    ParamFactory,
    apply_rope,
    constrain,
    layer_norm,
    maybe_remat,
    rms_norm,
    rope_frequencies,
    softmax_cross_entropy,
    split_tree,
    swiglu,
)

ACT3 = ("batch", None, None)  # hidden stream (B, S, D)
ACT_Q = ("batch", None, "heads", None)
ACT_KV = ("batch", None, "kv_heads", None)

__all__ = ["DenseLM"]


class DenseLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.inv_freq, self.rot = rope_frequencies(
            cfg.dh, base=cfg.rope_base, fraction=cfg.rope_fraction
        )

    def _mlp_params(self, f: ParamFactory, L: int) -> dict:
        cfg = self.cfg
        D, F = cfg.d_model, cfg.d_ff
        return {
            "w_gate": f.dense((L, D, F), ("layers", "embed", "mlp")),
            "w_up": f.dense((L, D, F), ("layers", "embed", "mlp")),
            "w_down": f.dense((L, F, D), ("layers", "mlp", "embed")),
        }

    # ------------------------------------------------------------------ init
    def init(self, key):
        cfg = self.cfg
        f = ParamFactory(key, dtype=cfg.dtype)
        L, D, H, KVH, Dh, F = (
            cfg.n_layers,
            cfg.d_model,
            cfg.n_heads,
            cfg.n_kv_heads,
            cfg.dh,
            cfg.d_ff,
        )
        V = cfg.padded_vocab
        blocks = {
            "wq": f.dense((L, D, H * Dh), ("layers", "embed", "heads_flat")),
            "wk": f.dense((L, D, KVH * Dh), ("layers", "embed", "kv_flat")),
            "wv": f.dense((L, D, KVH * Dh), ("layers", "embed", "kv_flat")),
            "wo": f.dense((L, H * Dh, D), ("layers", "heads_flat", "embed")),
            "ln1": f.ones((L, D), ("layers", "embed")),
            "ln2": f.ones((L, D), ("layers", "embed")),
            **self._mlp_params(f, L),
        }
        if cfg.qkv_bias:
            blocks["bq"] = f.zeros((L, H * Dh), ("layers", "heads_flat"))
            blocks["bk"] = f.zeros((L, KVH * Dh), ("layers", "kv_flat"))
            blocks["bv"] = f.zeros((L, KVH * Dh), ("layers", "kv_flat"))
        if cfg.norm == "layer":
            blocks["ln1b"] = f.zeros((L, D), ("layers", "embed"))
            blocks["ln2b"] = f.zeros((L, D), ("layers", "embed"))
        tree = {
            "embed": f.dense((V, D), ("vocab", "embed"), scale=0.02),
            "blocks": blocks,
            "ln_f": f.ones((D,), ("embed",)),
        }
        if cfg.norm == "layer":
            tree["ln_fb"] = f.zeros((D,), ("embed",))
        if not cfg.tie_embeddings:
            tree["unembed"] = f.dense((V, D), ("vocab", "embed"))
        return split_tree(tree)

    # ------------------------------------------------------------- internals
    def _norm(self, x, g, b):
        if self.cfg.norm == "layer":
            return layer_norm(x, g, b)
        return rms_norm(x, g)

    def _qkv(self, h, lp):
        cfg = self.cfg
        B, S, _ = h.shape
        q = jnp.einsum("bsd,df->bsf", h, lp["wq"])
        k = jnp.einsum("bsd,df->bsf", h, lp["wk"])
        v = jnp.einsum("bsd,df->bsf", h, lp["wv"])
        if cfg.qkv_bias:
            q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
        q = constrain(q.reshape(B, S, cfg.n_heads, cfg.dh), ACT_Q)
        k = constrain(k.reshape(B, S, cfg.n_kv_heads, cfg.dh), ACT_KV)
        v = constrain(v.reshape(B, S, cfg.n_kv_heads, cfg.dh), ACT_KV)
        return q, k, v

    def _mlp(self, hn, lp):
        """Feed-forward sub-block; overridden by the MoE family."""
        g = jax.nn.silu(jnp.einsum("...d,df->...f", hn, lp["w_gate"]))
        u = jnp.einsum("...d,df->...f", hn, lp["w_up"])
        gu = constrain(g * u, ("batch", None, "mlp"))
        return jnp.einsum("...f,fd->...d", gu, lp["w_down"])

    def _block_train(self, h, lp, positions):
        cfg = self.cfg
        h = constrain(h, ACT3)
        hn = self._norm(h, lp["ln1"], lp.get("ln1b"))
        q, k, v = self._qkv(hn, lp)
        q = apply_rope(q, positions, self.inv_freq, self.rot)
        k = apply_rope(k, positions, self.inv_freq, self.rot)
        o = attend(
            q, k, v, impl=cfg.attention_impl, causal=True,
            q_positions=positions, kv_positions=positions,
            window=cfg.window or None,
        )
        o = constrain(o, ACT_Q)
        o = jnp.einsum("bsf,fd->bsd", o.reshape(o.shape[0], o.shape[1], -1), lp["wo"])
        h = h + o
        hn = self._norm(h, lp["ln2"], lp.get("ln2b"))
        h = h + self._mlp(hn, lp)
        return h

    def _scan_train(self, params, h, positions):
        def body(carry, lp):
            return self._block_train(carry, lp, positions), None

        body = maybe_remat(body, self.cfg.remat_policy)
        if self.cfg.scan_layers:
            h, _ = jax.lax.scan(body, h, params["blocks"])
        else:
            L = self.cfg.n_layers
            for l in range(L):
                lp = jax.tree_util.tree_map(lambda x: x[l], params["blocks"])
                h = self._block_train(h, lp, positions)
        return h

    def _embed(self, params, tokens):
        return params["embed"][tokens].astype(self.cfg.dtype)

    def _logits(self, params, h):
        cfg = self.cfg
        table = params["embed"] if cfg.tie_embeddings else params["unembed"]
        logits = constrain(jnp.einsum("bsd,vd->bsv", h, table),
                           ("batch", None, "vocab"))
        if cfg.padded_vocab != cfg.vocab:  # mask padding rows
            pad = cfg.padded_vocab - cfg.vocab
            neg = jnp.full((*logits.shape[:-1], pad), -1e9, logits.dtype)
            logits = jnp.concatenate([logits[..., : cfg.vocab], neg], axis=-1)
        return logits

    def _forward_train(self, params, batch):
        cfg = self.cfg
        h = self._embed(params, batch["tokens"])
        B, S_text = batch["tokens"].shape
        if cfg.n_prefix_tokens:
            h = jnp.concatenate([batch["prefix_embeds"].astype(cfg.dtype), h], axis=1)
        S = h.shape[1]
        h = constrain(h, ACT3)
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        h = self._scan_train(params, h, positions)
        h = self._norm(h, params["ln_f"], params.get("ln_fb"))
        if cfg.n_prefix_tokens:
            h = h[:, cfg.n_prefix_tokens :]
        return self._logits(params, h)

    # ----------------------------------------------------------------- train
    def loss(self, params, batch):
        logits = self._forward_train(params, batch)
        labels = batch["labels"]
        mask = labels >= 0
        return softmax_cross_entropy(logits, jnp.maximum(labels, 0), mask)

    # ----------------------------------------------------------------- serve
    def make_caches(self, batch: int, s_max: int, *, abstract: bool = False):
        cfg = self.cfg
        mk = kv_cache_abstract if abstract else kv_cache_init
        return mk(cfg.n_layers, batch, s_max, cfg.n_kv_heads, cfg.dh, cfg.dtype)

    def cache_axes(self):
        kv = ("layers", "batch", "seq", "kv_heads", "head_dim")
        return KVCache(k=kv, v=kv, length=("batch",), positions=("batch", "seq"))

    def _attend_cached(self, q, ck, cv, cpos, qpos):
        cfg = self.cfg
        return attend(
            q, ck, cv, impl=cfg.attention_impl, causal=True,
            q_positions=qpos, kv_positions=cpos,
            window=cfg.window or None, kv_valid=cpos >= 0,
        )

    def _step(self, params, cache: KVCache, tokens, prefix_embeds=None,
              fresh: bool = False):
        """Shared prefill/decode: append S_q tokens and return last logits.

        ``fresh=True`` (prefill from an empty cache) attends over the
        in-flight K/V directly — this is what lets the streaming/chunked
        attention implementation engage on the 32k prefill hot path.
        """
        cfg = self.cfg
        h = self._embed(params, tokens)
        if prefix_embeds is not None:
            h = jnp.concatenate([prefix_embeds.astype(cfg.dtype), h], axis=1)
        B, Sq, _ = h.shape
        start = cache.length
        qpos = start[:, None] + jnp.arange(Sq, dtype=jnp.int32)[None, :]
        new_pos = kv_cache_slot_positions(cache.positions, qpos, start)

        def body(carry, xs):
            hh = constrain(carry, ACT3)
            lp, ck, cv = xs
            hn = self._norm(hh, lp["ln1"], lp.get("ln1b"))
            q, k, v = self._qkv(hn, lp)
            q = apply_rope(q, qpos, self.inv_freq, self.rot)
            k = apply_rope(k, qpos, self.inv_freq, self.rot)
            ck, cv = kv_cache_layer_update(ck, cv, k, v, start)
            if fresh and cfg.attention_impl == "chunked":
                # streaming attention over in-flight K/V (flash algorithm);
                # for the xla impl the cached path is better — its keys keep
                # the cache's seq sharding, which matters for archs whose
                # head counts cannot shard (qwen1.5-4b: 20 heads).
                o = attend(q, k, v, impl=cfg.attention_impl, causal=True,
                           q_positions=qpos, kv_positions=qpos,
                           window=cfg.window or None)
            else:
                o = self._attend_cached(q, ck, cv, new_pos, qpos)
            o = constrain(o, ACT_Q)
            o = jnp.einsum("bsf,fd->bsd", o.reshape(B, Sq, -1), lp["wo"])
            hh = hh + o
            hn = self._norm(hh, lp["ln2"], lp.get("ln2b"))
            hh = hh + self._mlp(hn, lp)
            return hh, (ck, cv)

        h, (nk, nv) = jax.lax.scan(body, h, (params["blocks"], cache.k, cache.v))
        h = self._norm(h, params["ln_f"], params.get("ln_fb"))
        logits = self._logits(params, h[:, -1:])
        new_cache = KVCache(k=nk, v=nv, length=start + Sq, positions=new_pos)
        return logits, new_cache

    def prefill(self, params, cache, batch):
        return self._step(
            params, cache, batch["tokens"], batch.get("prefix_embeds"),
            fresh=True,
        )

    def decode_step(self, params, cache, tokens):
        return self._step(params, cache, tokens)
