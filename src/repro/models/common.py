"""Shared neural building blocks for the model zoo (pure JAX, from scratch).

Conventions used across the zoo:
  - Parameters are nested dicts of jnp arrays; a *parallel* tree of logical-axis
    tuples (strings or None per dim) is produced alongside by every ``init``
    (see :mod:`repro.distributed.sharding` for the logical -> mesh mapping).
  - Layer stacks are weight-stacked with a leading ``layers`` dim and executed
    with ``jax.lax.scan`` so HLO size / compile time stay O(1) in depth.
  - Compute dtype is bf16, params bf16 (fp32 master copies live in the
    optimizer), softmax/norm statistics in fp32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

# ---------------------------------------------------------------------------
# Param/axes tree helpers
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ParamFactory:
    """Collects (init_fn, logical_axes) pairs so a model definition can emit
    the parameter tree and the logical-axes tree from the same source of truth.

    ``key=None`` switches to abstract mode: every method returns
    ShapeDtypeStructs instead of arrays (the dry-run path — no allocation).
    """

    key: jax.Array | None
    dtype: Any = jnp.bfloat16

    @property
    def abstract(self) -> bool:
        return self.key is None

    def _next_key(self) -> jax.Array:
        self.key, sub = jax.random.split(self.key)
        return sub

    def dense(self, shape, axes, *, scale: float | None = None, dtype=None):
        """Truncated-normal initialized weight. ``axes`` names every dim."""
        assert len(shape) == len(axes), (shape, axes)
        if self.abstract:
            return jax.ShapeDtypeStruct(shape, dtype or self.dtype), tuple(axes)
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        std = scale if scale is not None else 1.0 / np.sqrt(fan_in)
        w = jax.random.truncated_normal(
            self._next_key(), -2.0, 2.0, shape, jnp.float32
        ) * std
        return w.astype(dtype or self.dtype), tuple(axes)

    def zeros(self, shape, axes, *, dtype=None):
        assert len(shape) == len(axes), (shape, axes)
        if self.abstract:
            return jax.ShapeDtypeStruct(shape, dtype or self.dtype), tuple(axes)
        return jnp.zeros(shape, dtype or self.dtype), tuple(axes)

    def ones(self, shape, axes, *, dtype=None):
        assert len(shape) == len(axes), (shape, axes)
        if self.abstract:
            return jax.ShapeDtypeStruct(shape, dtype or self.dtype), tuple(axes)
        return jnp.ones(shape, dtype or self.dtype), tuple(axes)

    def value(self, arr, axes):
        arr = jnp.asarray(arr)
        assert arr.ndim == len(axes), (arr.shape, axes)
        if self.abstract:
            return jax.ShapeDtypeStruct(arr.shape, arr.dtype), tuple(axes)
        return arr, tuple(axes)


def split_tree(tree_of_pairs: PyTree) -> tuple[PyTree, PyTree]:
    """Split a tree whose leaves are (array, axes) into (params, axes_tree)."""
    leaves, treedef = jax.tree_util.tree_flatten(
        tree_of_pairs, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
        and (isinstance(x[1], tuple))
    )
    params = treedef.unflatten([a for a, _ in leaves])
    axes = treedef.unflatten([ax for _, ax in leaves])
    return params, axes


def tree_bytes(tree: PyTree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))


def param_count(tree: PyTree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


# ---------------------------------------------------------------------------
# Normalization
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, gamma: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32)).astype(x.dtype)


def layer_norm(
    x: jax.Array, gamma: jax.Array, beta: jax.Array, *, eps: float = 1e-5
) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (full or partial)
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, *, base: float = 10000.0, fraction: float = 1.0):
    """Inverse frequencies for the rotary-embedded prefix of the head dim."""
    rot = int(head_dim * fraction)
    rot -= rot % 2
    inv = 1.0 / (base ** (np.arange(0, rot, 2, dtype=np.float64) / rot))
    return jnp.asarray(inv, dtype=jnp.float32), rot


def apply_rope(
    x: jax.Array,  # (..., S, H, Dh)
    positions: jax.Array,  # (..., S) int32
    inv_freq: jax.Array,
    rot: int,
) -> jax.Array:
    """Rotate the first ``rot`` dims of each head; pass the rest through."""
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    ang = positions[..., None].astype(jnp.float32) * inv_freq  # (..., S, rot/2)
    cos = jnp.cos(ang)[..., None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x1 * sin + x2 * cos
    yr = jnp.stack([y1, y2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([yr.astype(x.dtype), xp], axis=-1)


# ---------------------------------------------------------------------------
# Activations / MLPs
# ---------------------------------------------------------------------------


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array):
    """SwiGLU MLP: down( silu(x @ gate) * (x @ up) )."""
    g = jax.nn.silu(jnp.einsum("...d,df->...f", x, w_gate))
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", g * u, w_down)


def gelu_mlp(x: jax.Array, w_in, b_in, w_out, b_out):
    h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, w_in) + b_in, approximate=True)
    return jnp.einsum("...f,fd->...d", h, w_out) + b_out


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def softmax_cross_entropy(
    logits: jax.Array,  # (..., V) any float dtype
    labels: jax.Array,  # (...,) int32
    mask: jax.Array | None = None,  # (...,) 1 = count
) -> jax.Array:
    """Mean CE over unmasked positions, computed in fp32."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# Activation sharding constraints
# ---------------------------------------------------------------------------
# XLA's sharding propagation loses the batch dim inside scanned layer bodies
# (observed: f32[256,2,4096,4096] attention scores with batch unsharded at
# 512 devices). Launchers activate a (mesh, rules) context and the models
# pin their hidden-stream/QKV/MLP/logit activations through it — the same
# approach production JAX frameworks take. Without a context (smoke tests,
# single device) ``constrain`` is the identity.

_ACT_CTX: list = []


class activation_sharding:
    """Context manager: route ``constrain`` through (mesh, rules)."""

    def __init__(self, mesh, rules):
        self.pair = (mesh, rules)

    def __enter__(self):
        _ACT_CTX.append(self.pair)
        return self

    def __exit__(self, *exc):
        _ACT_CTX.pop()
        return False


def constrain(x: jax.Array, names: tuple) -> jax.Array:
    """Pin a (possibly traced) activation to the planned sharding."""
    if not _ACT_CTX:
        return x
    mesh, rules = _ACT_CTX[-1]
    from repro.distributed.sharding import plan_sharding

    sh = plan_sharding(mesh, x.shape, names, rules)
    return jax.lax.with_sharding_constraint(x, sh)


# ---------------------------------------------------------------------------
# Remat (activation checkpointing) for scan bodies
# ---------------------------------------------------------------------------


def maybe_remat(body: Callable, policy: str) -> Callable:
    """Wrap a scan body with jax.checkpoint per the config's remat policy."""
    if policy == "none":
        return body
    if policy == "full":
        return jax.checkpoint(body)
    if policy == "dots":
        return jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    raise ValueError(f"unknown remat policy {policy!r}")


# ---------------------------------------------------------------------------
# Abstract init (dry-run path: no allocation)
# ---------------------------------------------------------------------------


def abstract_init(init_fn: Callable[[jax.Array], PyTree]) -> PyTree:
    """ShapeDtypeStruct tree of ``init_fn(key)`` without running it."""
    return jax.eval_shape(init_fn, jax.random.key(0))
