"""xLSTM LM (sLSTM + mLSTM blocks) — the assigned ``ssm``-family architecture.

Faithful to the xLSTM block topology (arXiv:2405.04517): mLSTM blocks are
pre-norm up-projection (factor ``mlstm_proj_factor``) blocks with a causal
depthwise conv, per-head matrix memory C in (d_k x d_v), exponential-style
input/forget gates, and an output gate branch; sLSTM blocks use a scalar
memory with block-diagonal (per-head) recurrence and a stabilizer state,
followed by a 4/3 GeLU MLP.

Layout: ``slstm_period`` groups layers into super-blocks of
(period-1 mLSTM + 1 sLSTM); super-blocks are weight-stacked and scanned.

Training runs the mLSTM in **chunkwise-parallel** form (intra-chunk quadratic
on a small chunk, inter-chunk recurrent state) — O(S * W) not O(S^2), which is
what makes the ``long_500k`` shape runnable for this family. The sLSTM is a
genuine sequential ``lax.scan`` over time (its nonlinearity does not admit a
parallel form). Decoding is O(1)-state recurrent for both.

Simplification vs the paper (recorded in DESIGN.md): input/forget gates use
log-sigmoid parameterization (bounded) rather than exp-gates with a running
max stabilizer for the mLSTM; the sLSTM keeps the exp-gate + stabilizer.
"""
from __future__ import annotations

import functools

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .api import ModelConfig
from .common import (
    ParamFactory,
    constrain,
    maybe_remat,
    rms_norm,
    softmax_cross_entropy,
    split_tree,
)

ACT3 = ("batch", None, None)
ACT_P = ("batch", None, "mlp")  # up-projected stream (B, S, pD)

__all__ = ["XLSTMLM", "XLSTMState"]

CHUNK = 128  # intra-chunk quadratic width for the chunkwise mLSTM


class XLSTMState(NamedTuple):
    """Recurrent serving state (the ssm analogue of a KV cache; O(1) in S)."""

    m_C: jax.Array  # (NSUP, PM, B, NH, dk, dv) fp32 matrix memory
    m_n: jax.Array  # (NSUP, PM, B, NH, dk) fp32 normalizer
    m_conv: jax.Array  # (NSUP, PM, B, w-1, pD) conv tail
    s_c: jax.Array  # (NSUP, B, D) fp32
    s_n: jax.Array  # (NSUP, B, D) fp32
    s_m: jax.Array  # (NSUP, B, D) fp32 stabilizer
    s_h: jax.Array  # (NSUP, B, D) hidden fed back into the recurrence
    s_conv: jax.Array  # (NSUP, B, w-1, D)
    length: jax.Array  # (B,) int32


def _causal_depthwise_conv(x: jax.Array, kernel: jax.Array) -> jax.Array:
    """x: (B, S, Cdim), kernel: (w, Cdim) -> causal depthwise conv, same length.

    Implemented as w shifted multiply-adds rather than lax.conv: XLA's conv
    partitioner cannot shard feature_group_count channels and replicates the
    whole input per layer (measured ~190 GB/step of all-reduce on the xlstm
    train cell — see EXPERIMENTS.md §Perf iteration B2); the shift-add form
    is elementwise and partitions cleanly over the channel axis.
    """
    w = kernel.shape[0]
    kf = kernel.astype(x.dtype)  # 4-tap conv is precision-insensitive; bf16
    out = x * kf[w - 1]          # halves the TP all-reduce bytes around it
    for t in range(1, w):
        shifted = jnp.pad(x[:, :-t, :], ((0, 0), (t, 0), (0, 0)))
        out = out + shifted * kf[w - 1 - t]
    return out


def _conv_step(x_t: jax.Array, tail: jax.Array, kernel: jax.Array):
    """Single-token causal conv. x_t: (B, C); tail: (B, w-1, C)."""
    window = jnp.concatenate([tail, x_t[:, None, :]], axis=1)  # (B, w, C)
    out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32), kernel.astype(jnp.float32))
    return out.astype(x_t.dtype), window[:, 1:, :]


def _slstm_step_math(xt, r4, c, n, m, hprev, NH, dh):
    """One sLSTM step. xt: (B,4,D); r4: (NH, dh, 4, dh) gate-major."""
    B, _, D = xt.shape
    hheads = hprev.reshape(B, NH, dh)
    rec = jnp.einsum("bhd,hdgf->bghf", hheads, r4).reshape(B, 4, D)
    g = xt + rec
    zt = jnp.tanh(g[:, 0])
    it = g[:, 1]
    ft = g[:, 2]
    ot = jax.nn.sigmoid(g[:, 3])
    m_new = jnp.maximum(ft + m, it)
    i_ = jnp.exp(it - m_new)
    f_ = jnp.exp(ft + m - m_new)
    c_new = f_ * c + i_ * zt
    n_new = f_ * n + i_
    h_new = ot * c_new / jnp.maximum(n_new, 1.0)
    return c_new, n_new, m_new, h_new


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7))
def _slstm_scan_core(wx4s, r, c0, n0, m0, h0, NH, dh):
    """Sequential sLSTM scan with a distribution-aware custom VJP.

    Why custom: reverse-mode through a plain lax.scan accumulates the shared
    recurrent-weight gradient dR (an outer product contracted over the
    *sharded* batch dim) inside the backward while loop — the partitioner
    then emits one all-reduce of dR per timestep (412 GB/step measured on
    the xlstm train cell at 256 chips). Here the backward scan emits the
    per-step gate gradients dg as stacked ys and dR is ONE einsum (and one
    all-reduce) after the loop. See EXPERIMENTS.md §Perf iteration B2.
    """
    # primal path (no differentiation): lean scan, no saved pre-states
    r4 = r.reshape(NH, dh, 4, dh)

    def step(carry, xt):
        out = _slstm_step_math(xt, r4, *carry, NH, dh)
        return out, out[3]

    finals, hs = jax.lax.scan(step, (c0, n0, m0, h0), wx4s)
    return finals, hs


def _slstm_scan_fwd_impl(wx4s, r, c0, n0, m0, h0, NH, dh):
    r4 = r.reshape(NH, dh, 4, dh)

    def step(carry, xt):
        c, n, m, hprev = carry
        out = _slstm_step_math(xt, r4, c, n, m, hprev, NH, dh)
        return out, (c, n, m, hprev)  # save PRE-step states for the bwd

    finals, pres = jax.lax.scan(step, (c0, n0, m0, h0), wx4s)
    hs = jnp.concatenate([pres[3][1:], finals[3][None]], axis=0)
    return finals, hs, pres


def _slstm_scan_core_fwd(wx4s, r, c0, n0, m0, h0, NH, dh):
    finals, hs, pres = _slstm_scan_fwd_impl(wx4s, r, c0, n0, m0, h0, NH, dh)
    return (finals, hs), (wx4s, r, pres)


def _slstm_scan_core_bwd(NH, dh, res, cts):
    wx4s, r, pres = res
    (dc_f, dn_f, dm_f, dh_f), dhs = cts
    r4 = r.reshape(NH, dh, 4, dh)
    S = wx4s.shape[0]

    def bwd_step(carry, xs):
        dc, dn, dm, dh_ = carry
        xt, c, n, m, hprev, dh_out = xs
        # recompute the step and pull gradients through it
        def f(xt_, hprev_, c_, n_, m_):
            return _slstm_step_math(xt_, r4, c_, n_, m_, hprev_, NH, dh)

        _, vjp = jax.vjp(f, xt, hprev, c, n, m)
        dxt, dhprev, dc_p, dn_p, dm_p = vjp((dc, dn, dm, dh_ + dh_out))
        return (dc_p, dn_p, dm_p, dhprev), (dxt, hprev)

    xs = (wx4s, *pres, dhs)
    xs_rev = jax.tree_util.tree_map(lambda a: a[::-1], xs)
    (dc0, dn0, dm0, dh0), (dxts_rev, hprev_rev) = jax.lax.scan(
        bwd_step, (dc_f, dn_f, dm_f, dh_f), xs_rev)
    dwx4s = dxts_rev[::-1]
    hprevs = hprev_rev[::-1]
    # dR in ONE contraction over (steps x batch) — a single all-reduce
    B = wx4s.shape[1]
    # g = xt + rec, so d(rec) = d(g) = dwx4s; regroup gate-major -> per-head
    drec5 = dwx4s.reshape(S, B, 4, NH, dh)
    dr4 = jnp.einsum("sbhd,sbghf->hdgf", hprevs.reshape(S, B, NH, dh), drec5)
    dr = dr4.reshape(NH, dh, 4 * dh)
    return dwx4s, dr, dc0, dn0, dm0, dh0


_slstm_scan_core.defvjp(_slstm_scan_core_fwd, _slstm_scan_core_bwd)


class XLSTMLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        period = cfg.slstm_period or cfg.n_layers
        assert cfg.n_layers % period == 0, (cfg.n_layers, period)
        self.n_sup = cfg.n_layers // period
        self.pm = period - 1 if cfg.slstm_period else period  # mLSTM layers per sup
        self.has_slstm = bool(cfg.slstm_period)
        self.pd = int(cfg.mlstm_proj_factor * cfg.d_model)
        self.nh = cfg.n_heads
        self.dv = self.pd // self.nh
        self.dk = max(self.dv // 2, 1)
        self.dh = cfg.d_model // self.nh
        # sLSTM MLP width: 4/3 * D rounded down to a multiple of 128 (>=128)
        self.fs = max((int(4 * cfg.d_model / 3) // 128) * 128, 128)

    # ------------------------------------------------------------------ init
    def init(self, key):
        cfg = self.cfg
        f = ParamFactory(key, dtype=cfg.dtype)
        D, pD, NH, dk, dv, w = cfg.d_model, self.pd, self.nh, self.dk, self.dv, cfg.conv_width
        NS, PM = self.n_sup, self.pm
        m = {
            "ln": f.ones((NS, PM, D), ("sup", "layers", "embed")),
            "w_up": f.dense((NS, PM, D, 2 * pD), ("sup", "layers", "embed", "mlp")),
            "conv": f.dense((NS, PM, w, pD), ("sup", "layers", None, "mlp"), scale=0.5),
            "wq": f.dense((NS, PM, pD, NH * dk), ("sup", "layers", "mlp", "heads_flat")),
            "wk": f.dense((NS, PM, pD, NH * dk), ("sup", "layers", "mlp", "heads_flat")),
            "wv": f.dense((NS, PM, pD, NH * dv), ("sup", "layers", "mlp", "mlp")),
            "w_if": f.dense((NS, PM, pD, 2 * NH), ("sup", "layers", "mlp", None)),
            "b_if": f.value(
                jnp.tile(jnp.array([1.0] * NH + [3.0] * NH, jnp.float32), (NS, PM, 1)),
                ("sup", "layers", None),
            ),  # bias forget gates open, input gates mildly open
            "w_down": f.dense((NS, PM, pD, D), ("sup", "layers", "mlp", "embed")),
        }
        tree = {"m": m, "embed": f.dense((cfg.padded_vocab, D), ("vocab", "embed"), scale=0.02),
                "ln_f": f.ones((D,), ("embed",)),
                "unembed": f.dense((cfg.padded_vocab, D), ("vocab", "embed"))}
        if self.has_slstm:
            dh = self.dh
            tree["s"] = {
                "ln": f.ones((NS, D), ("sup", "embed")),
                "conv": f.dense((NS, w, D), ("sup", None, "embed"), scale=0.5),
                "w": f.dense((NS, D, 4 * D), ("sup", "embed", "mlp")),
                "r": f.dense((NS, NH, dh, 4 * dh), ("sup", "heads", None, None)),
                "b": f.value(
                    jnp.tile(
                        jnp.concatenate([
                            jnp.zeros((D,)), jnp.zeros((D,)),
                            3.0 * jnp.ones((D,)), jnp.zeros((D,))]).astype(jnp.float32),
                        (NS, 1),
                    ),
                    ("sup", None),
                ),
                "ln2": f.ones((NS, D), ("sup", "embed")),
                "w_mlp_up": f.dense((NS, D, self.fs), ("sup", "embed", "mlp")),
                "w_mlp_down": f.dense((NS, self.fs, D), ("sup", "mlp", "embed")),
            }
        return split_tree(tree)

    # --------------------------------------------------------- mLSTM (train)
    def _mlstm_chunkwise(self, q, k, v, li, lf):
        """Chunkwise-parallel mLSTM scan.

        q,k: (B, S, NH, dk); v: (B, S, NH, dv); li/lf: (B, S, NH) log-gates (<=0).
        Returns h: (B, S, NH, dv).
        """
        B, S, NH, dk = q.shape
        dv = v.shape[-1]
        W = CHUNK
        while S % W != 0:
            W //= 2
        nC = S // W
        scale = dk**-0.5
        # bf16 operands + fp32 accumulation: MXU-native, halves HBM traffic
        qc = (q.reshape(B, nC, W, NH, dk) * scale).astype(q.dtype)
        kc = k.reshape(B, nC, W, NH, dk)
        vc = v.reshape(B, nC, W, NH, dv)
        lic = li.reshape(B, nC, W, NH)
        lfc = lf.reshape(B, nC, W, NH)
        causal = jnp.tril(jnp.ones((W, W), bool))

        def chunk_body(carry, xs):
            C, n = carry  # (B, NH, dk, dv), (B, NH, dk)
            qq, kk, vv, ll_i, ll_f = xs  # (B, W, NH, *)
            F = jnp.cumsum(ll_f, axis=1)  # (B, W, NH) decay from chunk start
            # intra-chunk: weight(t, s) = exp(F_t - F_s + li_s), s <= t
            logits = jnp.einsum("bthd,bshd->bhts", qq, kk,
                                preferred_element_type=jnp.float32)
            wts = F[:, :, None, :] - F[:, None, :, :] + ll_i[:, None, :, :]  # (B,t,s,NH)
            wts = jnp.where(causal[None, :, :, None], wts, -jnp.inf)
            intra = jnp.einsum(
                "bhts,bshv->bthv",
                (logits * jnp.exp(wts).transpose(0, 3, 1, 2)).astype(qq.dtype),
                vv, preferred_element_type=jnp.float32)
            # inter-chunk: q_t reads the incoming state decayed by exp(F_t)
            inter = jnp.einsum("bthd,bhdv->bthv",
                               qq.astype(jnp.float32) * jnp.exp(F)[..., None], C)
            # normalizer
            n_run = jnp.exp(F)[..., None] * n[:, None] + jnp.einsum(
                "bhts,bshd->bthd", jnp.exp(wts).transpose(0, 3, 1, 2),
                kk.astype(jnp.float32))
            denom = jnp.abs(jnp.einsum("bthd,bthd->bth",
                                       qq.astype(jnp.float32), n_run))
            h = (intra + inter) / jnp.maximum(denom, 1.0)[..., None]
            # state update to end of chunk
            Fw = F[:, -1, :]  # (B, NH)
            decay_s = jnp.exp(Fw[:, None] - F + ll_i)  # (B, W, NH)
            C = jnp.exp(Fw)[..., None, None] * C + jnp.einsum(
                "bshd,bsh,bshv->bhdv", kk.astype(jnp.float32), decay_s,
                vv.astype(jnp.float32))
            n = jnp.exp(Fw)[..., None] * n + jnp.einsum(
                "bshd,bsh->bhd", kk.astype(jnp.float32), decay_s)
            return (C, n), h

        C0 = jnp.zeros((B, NH, dk, dv), jnp.float32)
        n0 = jnp.zeros((B, NH, dk), jnp.float32)
        xs = tuple(jnp.moveaxis(a, 1, 0) for a in (qc, kc, vc, lic, lfc))
        (_, _), hs = jax.lax.scan(chunk_body, (C0, n0), xs)
        h = jnp.moveaxis(hs, 0, 1).reshape(B, S, NH, dv)
        return h.astype(q.dtype)

    def _mlstm_chunkwise_stateful(self, q, k, v, li, lf, C0, n0):
        """Same as above but threads an incoming state (prefill path)."""
        B, S, NH, dk = q.shape
        dv = v.shape[-1]
        W = CHUNK
        while S % W != 0:
            W //= 2
        nC = S // W
        scale = dk**-0.5
        # bf16 operands + fp32 accumulation: MXU-native, halves HBM traffic
        qc = (q.reshape(B, nC, W, NH, dk) * scale).astype(q.dtype)
        kc = k.reshape(B, nC, W, NH, dk)
        vc = v.reshape(B, nC, W, NH, dv)
        lic = li.reshape(B, nC, W, NH)
        lfc = lf.reshape(B, nC, W, NH)
        causal = jnp.tril(jnp.ones((W, W), bool))

        def chunk_body(carry, xs):
            C, n = carry
            qq, kk, vv, ll_i, ll_f = xs
            F = jnp.cumsum(ll_f, axis=1)
            logits = jnp.einsum("bthd,bshd->bhts", qq, kk,
                                preferred_element_type=jnp.float32)
            wts = F[:, :, None, :] - F[:, None, :, :] + ll_i[:, None, :, :]
            wts = jnp.where(causal[None, :, :, None], wts, -jnp.inf)
            intra = jnp.einsum(
                "bhts,bshv->bthv",
                (logits * jnp.exp(wts).transpose(0, 3, 1, 2)).astype(qq.dtype),
                vv, preferred_element_type=jnp.float32)
            inter = jnp.einsum("bthd,bhdv->bthv",
                               qq.astype(jnp.float32) * jnp.exp(F)[..., None], C)
            n_run = jnp.exp(F)[..., None] * n[:, None] + jnp.einsum(
                "bhts,bshd->bthd", jnp.exp(wts).transpose(0, 3, 1, 2),
                kk.astype(jnp.float32))
            denom = jnp.abs(jnp.einsum("bthd,bthd->bth",
                                       qq.astype(jnp.float32), n_run))
            h = (intra + inter) / jnp.maximum(denom, 1.0)[..., None]
            Fw = F[:, -1, :]
            decay_s = jnp.exp(Fw[:, None] - F + ll_i)
            C = jnp.exp(Fw)[..., None, None] * C + jnp.einsum(
                "bshd,bsh,bshv->bhdv", kk.astype(jnp.float32), decay_s,
                vv.astype(jnp.float32))
            n = jnp.exp(Fw)[..., None] * n + jnp.einsum(
                "bshd,bsh->bhd", kk.astype(jnp.float32), decay_s)
            return (C, n), h

        xs = tuple(jnp.moveaxis(a, 1, 0) for a in (qc, kc, vc, lic, lfc))
        (C1, n1), hs = jax.lax.scan(chunk_body, (C0, n0), xs)
        h = jnp.moveaxis(hs, 0, 1).reshape(B, S, NH, dv)
        return h.astype(q.dtype), C1, n1

    # ------------------------------------------------------------- mLSTM block
    def _mlstm_qkvif(self, xm, xc, lp):
        """q, k, gates from the conv branch ``xc``; v from the raw branch ``xm``."""
        B, S, _ = xm.shape
        NH, dk, dv = self.nh, self.dk, self.dv
        q = jnp.einsum("bsp,pf->bsf", xc, lp["wq"]).reshape(B, S, NH, dk)
        k = jnp.einsum("bsp,pf->bsf", xc, lp["wk"]).reshape(B, S, NH, dk)
        v = jnp.einsum("bsp,pf->bsf", xm, lp["wv"]).reshape(B, S, NH, dv)
        # bf16 operands, fp32 accumulation: keeps d(xc) in bf16 (the f32 gate
        # path otherwise drags 1 GiB f32 all-reduces through the backward)
        gf = jnp.einsum("bsp,pg->bsg", xc, lp["w_if"].astype(xc.dtype),
                        preferred_element_type=jnp.float32)
        gf = gf + lp["b_if"].astype(jnp.float32)
        li = jax.nn.log_sigmoid(gf[..., :NH])
        lf = jax.nn.log_sigmoid(gf[..., NH:])
        return q, k, v, li, lf

    def _mlstm_block_train(self, h, lp):
        cfg = self.cfg
        B, S, D = h.shape
        h = constrain(h, ACT3)
        hn = rms_norm(h, lp["ln"])
        up = jnp.einsum("bsd,dp->bsp", hn, lp["w_up"])
        xm, z = jnp.split(up, 2, axis=-1)
        xm, z = constrain(xm, ACT_P), constrain(z, ACT_P)
        xc = jax.nn.silu(_causal_depthwise_conv(xm, lp["conv"]))
        q, k, v, li, lf = self._mlstm_qkvif(xm, xc, lp)
        ht = self._mlstm_chunkwise(q, k, v, li, lf)  # (B,S,NH,dv)
        out = constrain(ht.reshape(B, S, -1), ACT_P) * jax.nn.silu(z)
        return h + jnp.einsum("bsp,pd->bsd", out, lp["w_down"])

    # ------------------------------------------------------------- sLSTM block
    def _slstm_scan(self, x, sp, c0, n0, m0, h0):
        """x: (B, S, D) conv output. Sequential scan over time.

        The big gate projection runs TP-sharded *outside* the scan; its
        output is then regrouped (B, S, 4, D) and pinned replicated-on-model
        BEFORE entering the scan — otherwise every per-step gate slice of a
        model-sharded (B, 4D) tensor reshards inside the 4096-iteration loop
        (measured: that single effect made this family the most
        collective-bound cell of the whole zoo; see EXPERIMENTS.md §Perf).
        """
        cfg = self.cfg
        B, S, D = x.shape
        NH, dh = self.nh, self.dh

        wx = jnp.einsum("bsd,dg->bsg", x.astype(jnp.float32), sp["w"].astype(jnp.float32))
        wx = wx + sp["b"].astype(jnp.float32)  # (B, S, 4D)
        wx4 = constrain(wx.reshape(B, S, 4, D), ("batch", None, None, None))

        (c1, n1, m1, h1), hs = _slstm_scan_core(
            jnp.moveaxis(wx4, 1, 0), sp["r"].astype(jnp.float32),
            c0, n0, m0, h0, NH, dh)
        return jnp.moveaxis(hs, 0, 1), (c1, n1, m1, h1)

    def _slstm_block_train(self, h, sp):
        cfg = self.cfg
        B, S, D = h.shape
        hn = rms_norm(h, sp["ln"])
        xc = jax.nn.silu(_causal_depthwise_conv(hn, sp["conv"]))
        z = jnp.zeros((B, D), jnp.float32)
        hs, _ = self._slstm_scan(xc, sp, z, z, jnp.full_like(z, -1e9), z)
        h = h + hs.astype(h.dtype)
        hn = rms_norm(h, sp["ln2"])
        mlp = jnp.einsum("bsf,fd->bsd", jax.nn.gelu(
            jnp.einsum("bsd,df->bsf", hn, sp["w_mlp_up"]), approximate=True), sp["w_mlp_down"])
        return h + mlp

    # ----------------------------------------------------------------- train
    def _forward_train(self, params, batch):
        cfg = self.cfg
        h = params["embed"][batch["tokens"]].astype(cfg.dtype)

        def sup_body(carry, xs):
            hh = carry
            if self.has_slstm:
                mp, sp = xs
            else:
                (mp,) = xs

            def m_body(c, lp):
                return self._mlstm_block_train(c, lp), None

            hh, _ = jax.lax.scan(m_body, hh, mp)
            if self.has_slstm:
                hh = self._slstm_block_train(hh, sp)
            return hh, None

        xs = (params["m"], params["s"]) if self.has_slstm else (params["m"],)
        h, _ = jax.lax.scan(maybe_remat(sup_body, cfg.remat_policy), h, xs)
        h = rms_norm(h, params["ln_f"])
        logits = jnp.einsum("bsd,vd->bsv", h, params["unembed"])
        if cfg.padded_vocab != cfg.vocab:
            pad = cfg.padded_vocab - cfg.vocab
            neg = jnp.full((*logits.shape[:-1], pad), -1e9, logits.dtype)
            logits = jnp.concatenate([logits[..., : cfg.vocab], neg], axis=-1)
        return logits

    def loss(self, params, batch):
        logits = self._forward_train(params, batch)
        labels = batch["labels"]
        return softmax_cross_entropy(logits, jnp.maximum(labels, 0), labels >= 0)

    # ----------------------------------------------------------------- serve
    def make_caches(self, batch: int, s_max: int, *, abstract: bool = False):
        cfg = self.cfg
        NS, PM, NH, dk, dv = self.n_sup, self.pm, self.nh, self.dk, self.dv
        D, pD, w = cfg.d_model, self.pd, cfg.conv_width
        shapes = dict(
            m_C=((NS, PM, batch, NH, dk, dv), jnp.float32),
            m_n=((NS, PM, batch, NH, dk), jnp.float32),
            m_conv=((NS, PM, batch, w - 1, pD), cfg.dtype),
            s_c=((NS, batch, D), jnp.float32),
            s_n=((NS, batch, D), jnp.float32),
            s_m=((NS, batch, D), jnp.float32),
            s_h=((NS, batch, D), jnp.float32),
            s_conv=((NS, batch, w - 1, D), cfg.dtype),
            length=((batch,), jnp.int32),
        )
        if abstract:
            vals = {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in shapes.items()}
        else:
            vals = {k: jnp.zeros(s, d) for k, (s, d) in shapes.items()}
            vals["s_m"] = jnp.full_like(vals["s_m"], -1e9)
        return XLSTMState(**vals)

    def cache_axes(self):
        return XLSTMState(
            m_C=("sup", "layers", "batch", "heads", "head_dim", "mlp"),
            m_n=("sup", "layers", "batch", "heads", "head_dim"),
            m_conv=("sup", "layers", "batch", None, "mlp"),
            s_c=("sup", "batch", "embed"),
            s_n=("sup", "batch", "embed"),
            s_m=("sup", "batch", "embed"),
            s_h=("sup", "batch", "embed"),
            s_conv=("sup", "batch", None, "embed"),
            length=("batch",),
        )

    def _decode_mlstm(self, h, lp, C, n, conv_tail):
        """Single-token mLSTM update. h: (B, 1, D)."""
        B = h.shape[0]
        NH, dk, dv = self.nh, self.dk, self.dv
        hn = rms_norm(h[:, 0], lp["ln"])
        up = jnp.einsum("bd,dp->bp", hn, lp["w_up"])
        xm, z = jnp.split(up, 2, axis=-1)
        xc, conv_tail = _conv_step(xm, conv_tail, lp["conv"])
        xc = jax.nn.silu(xc)
        q = jnp.einsum("bp,pf->bf", xc, lp["wq"]).reshape(B, NH, dk).astype(jnp.float32)
        k = jnp.einsum("bp,pf->bf", xc, lp["wk"]).reshape(B, NH, dk).astype(jnp.float32)
        v = jnp.einsum("bp,pf->bf", xm, lp["wv"]).reshape(B, NH, dv).astype(jnp.float32)
        gf = jnp.einsum("bp,pg->bg", xc.astype(jnp.float32),
                        lp["w_if"].astype(jnp.float32)) + lp["b_if"].astype(jnp.float32)
        i_ = jnp.exp(jax.nn.log_sigmoid(gf[:, :NH]))
        f_ = jnp.exp(jax.nn.log_sigmoid(gf[:, NH:]))
        C = f_[..., None, None] * C + i_[..., None, None] * jnp.einsum("bhd,bhv->bhdv", k, v)
        n = f_[..., None] * n + i_[..., None] * k
        q = q * (dk**-0.5)
        num = jnp.einsum("bhd,bhdv->bhv", q, C)
        den = jnp.abs(jnp.einsum("bhd,bhd->bh", q, n))
        ht = (num / jnp.maximum(den, 1.0)[..., None]).astype(h.dtype)
        out = ht.reshape(B, -1) * jax.nn.silu(z)
        return h + jnp.einsum("bp,pd->bd", out, lp["w_down"])[:, None], C, n, conv_tail

    def _decode_slstm(self, h, sp, c, n, m, hprev, conv_tail):
        B = h.shape[0]
        D = self.cfg.d_model
        hn = rms_norm(h[:, 0], sp["ln"])
        xc, conv_tail = _conv_step(hn, conv_tail, sp["conv"])
        xc = jax.nn.silu(xc)
        wx = jnp.einsum("bd,dg->bg", xc.astype(jnp.float32), sp["w"].astype(jnp.float32))
        wx = wx + sp["b"].astype(jnp.float32)
        hs, (c, n, m, hprev) = self._slstm_step(wx, sp, c, n, m, hprev)
        h = h + hs[:, None].astype(h.dtype)
        hn = rms_norm(h[:, 0], sp["ln2"])
        mlp = jnp.einsum("bf,fd->bd", jax.nn.gelu(
            jnp.einsum("bd,df->bf", hn, sp["w_mlp_up"]), approximate=True), sp["w_mlp_down"])
        return h + mlp[:, None], c, n, m, hprev, conv_tail

    def _slstm_step(self, wx, sp, c, n, m, hprev):
        B = wx.shape[0]
        D = self.cfg.d_model
        NH, dh = self.nh, self.dh
        hheads = hprev.reshape(B, NH, dh)
        rec = jnp.einsum("bhd,hdg->bhg", hheads, sp["r"].astype(jnp.float32))
        rec4 = rec.reshape(B, NH, 4, dh).transpose(0, 2, 1, 3).reshape(B, 4 * D)
        g = wx + rec4
        zt = jnp.tanh(g[:, :D])
        it = g[:, D : 2 * D]
        ft = g[:, 2 * D : 3 * D]
        ot = jax.nn.sigmoid(g[:, 3 * D :])
        m_new = jnp.maximum(ft + m, it)
        i_ = jnp.exp(it - m_new)
        f_ = jnp.exp(ft + m - m_new)
        c_new = f_ * c + i_ * zt
        n_new = f_ * n + i_
        h_new = ot * c_new / jnp.maximum(n_new, 1.0)
        return h_new, (c_new, n_new, m_new, h_new)

    def decode_step(self, params, state: XLSTMState, tokens):
        cfg = self.cfg
        h = params["embed"][tokens].astype(cfg.dtype)  # (B, 1, D)

        def sup_body(carry, xs):
            hh = carry
            if self.has_slstm:
                mp, sp, mC, mn, mcv, sc, sn, sm, sh, scv = xs
            else:
                mp, mC, mn, mcv = xs

            def m_body(c, x):
                lp, C, n, tail = x
                c, C, n, tail = self._decode_mlstm(c, lp, C, n, tail)
                return c, (C, n, tail)

            hh, (mC, mn, mcv) = jax.lax.scan(m_body, hh, (mp, mC, mn, mcv))
            if self.has_slstm:
                hh, sc, sn, sm, sh, scv = self._decode_slstm(hh, sp, sc, sn, sm, sh, scv)
                return hh, (mC, mn, mcv, sc, sn, sm, sh, scv)
            return hh, (mC, mn, mcv)

        if self.has_slstm:
            xs = (params["m"], params["s"], state.m_C, state.m_n, state.m_conv,
                  state.s_c, state.s_n, state.s_m, state.s_h, state.s_conv)
            h, (mC, mn, mcv, sc, sn, sm, sh, scv) = jax.lax.scan(sup_body, h, xs)
            new = state._replace(m_C=mC, m_n=mn, m_conv=mcv, s_c=sc, s_n=sn,
                                 s_m=sm, s_h=sh, s_conv=scv, length=state.length + 1)
        else:
            xs = (params["m"], state.m_C, state.m_n, state.m_conv)
            h, (mC, mn, mcv) = jax.lax.scan(sup_body, h, xs)
            new = state._replace(m_C=mC, m_n=mn, m_conv=mcv, length=state.length + 1)

        h = rms_norm(h, params["ln_f"])
        logits = jnp.einsum("bsd,vd->bsv", h, params["unembed"])
        if cfg.padded_vocab != cfg.vocab:
            logits = logits[..., : cfg.vocab]
        return logits, new

    def prefill(self, params, state: XLSTMState, batch):
        """Process a prompt and return (last_logits, state).

        Runs the chunkwise-parallel form token-exactly; conv tails and sLSTM
        states are threaded through. For simplicity the prompt is processed by
        repeated decode over the last (conv_width-1) tokens after a chunkwise
        main pass would be needed for conv continuity; instead we process the
        whole prompt with the train-form conv (correct for a fresh state) and
        capture the final recurrent states by scanning per super-block.
        """
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        h = params["embed"][tokens].astype(cfg.dtype)

        def sup_body(carry, xs):
            hh = carry
            if self.has_slstm:
                mp, sp = xs
            else:
                (mp,) = xs

            def m_body(c, lp):
                hn = rms_norm(c, lp["ln"])
                up = jnp.einsum("bsd,dp->bsp", hn, lp["w_up"])
                xm, z = jnp.split(up, 2, axis=-1)
                xc = jax.nn.silu(_causal_depthwise_conv(xm, lp["conv"]))
                q, k, v, li, lf = self._mlstm_qkvif(xm, xc, lp)
                C0 = jnp.zeros((B, self.nh, self.dk, self.dv), jnp.float32)
                n0 = jnp.zeros((B, self.nh, self.dk), jnp.float32)
                ht, C1, n1 = self._mlstm_chunkwise_stateful(q, k, v, li, lf, C0, n0)
                out = ht.reshape(B, S, -1) * jax.nn.silu(z)
                c = c + jnp.einsum("bsp,pd->bsd", out, lp["w_down"])
                tail = xm[:, S - (cfg.conv_width - 1) :, :]  # conv context for decode
                return c, (C1, n1, tail)

            hh, (mC, mn, mcv) = jax.lax.scan(m_body, hh, mp)
            if self.has_slstm:
                hn = rms_norm(hh, sp["ln"])
                tail_s = hn[:, S - (cfg.conv_width - 1) :, :]  # conv context for decode
                xc = jax.nn.silu(_causal_depthwise_conv(hn, sp["conv"]))
                D = cfg.d_model
                z = jnp.zeros((B, D), jnp.float32)
                hs, (c1, n1, m1, h1) = self._slstm_scan(
                    xc, sp, z, z, jnp.full_like(z, -1e9), z)
                hh = hh + hs.astype(hh.dtype)
                hn2 = rms_norm(hh, sp["ln2"])
                mlp = jnp.einsum("bsf,fd->bsd", jax.nn.gelu(
                    jnp.einsum("bsd,df->bsf", hn2, sp["w_mlp_up"]), approximate=True),
                    sp["w_mlp_down"])
                hh = hh + mlp
                return hh, (mC, mn, mcv, c1, n1, m1, h1, tail_s)
            return hh, (mC, mn, mcv)

        xs = (params["m"], params["s"]) if self.has_slstm else (params["m"],)
        if self.has_slstm:
            h, (mC, mn, mcv, sc, sn, sm, sh, scv) = jax.lax.scan(sup_body, h, xs)
            new = state._replace(m_C=mC, m_n=mn, m_conv=mcv, s_c=sc, s_n=sn, s_m=sm,
                                 s_h=sh, s_conv=scv, length=state.length + S)
        else:
            h, (mC, mn, mcv) = jax.lax.scan(sup_body, h, xs)
            new = state._replace(m_C=mC, m_n=mn, m_conv=mcv, length=state.length + S)
        h = rms_norm(h[:, -1:], params["ln_f"])
        logits = jnp.einsum("bsd,vd->bsv", h, params["unembed"])
        if cfg.padded_vocab != cfg.vocab:
            logits = logits[..., : cfg.vocab]
        return logits, new
