"""Encoder-decoder transformer (seamless-m4t-large-v2 backbone, audio family).

The speech frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings ``src_frames`` (B, S_src, D). The backbone is a
standard pre-LayerNorm enc-dec transformer: ``enc_layers`` bidirectional
self-attention layers over the frames, ``dec_layers`` causal self-attention +
cross-attention layers over target tokens. GeLU MLPs with biases, learned
absolute positions would be frontend-specific — we use RoPE on self-attention
(decoder) and no positional term on the encoder (frames already carry
positional structure from the stub frontend).

``batch`` keys:
  train  : src_frames (B,Ss,D), tokens (B,St), labels (B,St)
  prefill: src_frames, tokens (target prefix)
  decode : tokens (B,1)

Caches: decoder self-attention KV cache + per-layer projected encoder
K/V (cross cache), both built at prefill.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .api import ModelConfig
from .attention import attend, kv_cache_layer_update, kv_cache_slot_positions
from .common import (
    ParamFactory,
    apply_rope,
    constrain,
    gelu_mlp,
    layer_norm,
    maybe_remat,
    rope_frequencies,
    softmax_cross_entropy,
    split_tree,
)

ACT3 = ("batch", None, None)
ACT_H = ("batch", None, "heads", None)

__all__ = ["EncDecLM", "EncDecCache"]


class EncDecCache(NamedTuple):
    self_k: jax.Array  # (Ld, B, S_max, KVH, dh)
    self_v: jax.Array
    self_pos: jax.Array  # (Ld, B, S_max)
    cross_k: jax.Array  # (Ld, B, S_src, KVH, dh)
    cross_v: jax.Array
    length: jax.Array  # (B,)


class EncDecLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        assert cfg.enc_layers and cfg.dec_layers
        self.inv_freq, self.rot = rope_frequencies(cfg.dh, base=cfg.rope_base)

    # ------------------------------------------------------------------ init
    def _attn_p(self, f, L, kv=True):
        cfg = self.cfg
        D, H, KVH, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.dh
        p = {
            "wq": f.dense((L, D, H * dh), ("layers", "embed", "heads_flat")),
            "wo": f.dense((L, H * dh, D), ("layers", "heads_flat", "embed")),
            "ln": f.ones((L, D), ("layers", "embed")),
            "lnb": f.zeros((L, D), ("layers", "embed")),
        }
        if kv:
            p["wk"] = f.dense((L, D, KVH * dh), ("layers", "embed", "kv_flat"))
            p["wv"] = f.dense((L, D, KVH * dh), ("layers", "embed", "kv_flat"))
        return p

    def _mlp_p(self, f, L):
        cfg = self.cfg
        D, F = cfg.d_model, cfg.d_ff
        return {
            "w_in": f.dense((L, D, F), ("layers", "embed", "mlp")),
            "b_in": f.zeros((L, F), ("layers", "mlp")),
            "w_out": f.dense((L, F, D), ("layers", "mlp", "embed")),
            "b_out": f.zeros((L, D), ("layers", "embed")),
            "ln_m": f.ones((L, D), ("layers", "embed")),
            "ln_mb": f.zeros((L, D), ("layers", "embed")),
        }

    def init(self, key):
        cfg = self.cfg
        f = ParamFactory(key, dtype=cfg.dtype)
        Le, Ld = cfg.enc_layers, cfg.dec_layers
        V, D = cfg.padded_vocab, cfg.d_model
        tree = {
            "enc": {**{f"sa_{k}": v for k, v in self._attn_p(f, Le).items()},
                    **self._mlp_p(f, Le)},
            "dec": {
                **{f"sa_{k}": v for k, v in self._attn_p(f, Ld).items()},
                **{f"ca_{k}": v for k, v in self._attn_p(f, Ld).items()},
                **self._mlp_p(f, Ld),
            },
            "embed": f.dense((V, D), ("vocab", "embed"), scale=0.02),
            "ln_enc": f.ones((D,), ("embed",)),
            "ln_encb": f.zeros((D,), ("embed",)),
            "ln_f": f.ones((D,), ("embed",)),
            "ln_fb": f.zeros((D,), ("embed",)),
            "unembed": f.dense((V, D), ("vocab", "embed")),
        }
        return split_tree(tree)

    # ---------------------------------------------------------------- encoder
    def _qkv(self, h, wq, wk, wv):
        cfg = self.cfg
        B, S, _ = h.shape
        q = constrain(jnp.einsum("bsd,df->bsf", h, wq).reshape(
            B, S, cfg.n_heads, cfg.dh), ACT_H)
        k = constrain(jnp.einsum("bsd,df->bsf", h, wk).reshape(
            B, S, cfg.n_kv_heads, cfg.dh), ("batch", None, "kv_heads", None))
        v = constrain(jnp.einsum("bsd,df->bsf", h, wv).reshape(
            B, S, cfg.n_kv_heads, cfg.dh), ("batch", None, "kv_heads", None))
        return q, k, v

    def encode(self, params, src_frames):
        cfg = self.cfg
        h = src_frames.astype(cfg.dtype)
        B, S, _ = h.shape

        def body(carry, lp):
            hh = constrain(carry, ACT3)
            hn = layer_norm(hh, lp["sa_ln"], lp["sa_lnb"])
            q, k, v = self._qkv(hn, lp["sa_wq"], lp["sa_wk"], lp["sa_wv"])
            o = constrain(attend(q, k, v, impl=cfg.attention_impl, causal=False), ACT_H)
            hh = hh + jnp.einsum("bsf,fd->bsd", o.reshape(B, S, -1), lp["sa_wo"])
            hn = layer_norm(hh, lp["ln_m"], lp["ln_mb"])
            hh = hh + gelu_mlp(hn, lp["w_in"], lp["b_in"], lp["w_out"], lp["b_out"])
            return hh, None

        h, _ = jax.lax.scan(maybe_remat(body, cfg.remat_policy), h, params["enc"])
        return layer_norm(h, params["ln_enc"], params["ln_encb"])

    # ---------------------------------------------------------------- decoder
    def _dec_block(self, hh, lp, *, self_k, self_v, self_pos, qpos,
                   cross_k, cross_v, B, Sq):
        cfg = self.cfg
        hn = layer_norm(hh, lp["sa_ln"], lp["sa_lnb"])
        q, k, v = self._qkv(hn, lp["sa_wq"], lp["sa_wk"], lp["sa_wv"])
        q = apply_rope(q, qpos, self.inv_freq, self.rot)
        k = apply_rope(k, qpos, self.inv_freq, self.rot)
        o = attend(q, self_k, self_v, impl=cfg.attention_impl, causal=True,
                   q_positions=qpos, kv_positions=self_pos, kv_valid=self_pos >= 0) \
            if self_k is not None else \
            attend(q, k, v, impl=cfg.attention_impl, causal=True,
                   q_positions=qpos, kv_positions=qpos)
        hh = hh + jnp.einsum("bsf,fd->bsd", o.reshape(B, Sq, -1), lp["sa_wo"])
        # cross attention
        hn = layer_norm(hh, lp["ca_ln"], lp["ca_lnb"])
        qc = jnp.einsum("bsd,df->bsf", hn, lp["ca_wq"]).reshape(B, Sq, cfg.n_heads, cfg.dh)
        oc = attend(qc, cross_k, cross_v, impl=cfg.attention_impl, causal=False)
        hh = hh + jnp.einsum("bsf,fd->bsd", oc.reshape(B, Sq, -1), lp["ca_wo"])
        hn = layer_norm(hh, lp["ln_m"], lp["ln_mb"])
        hh = hh + gelu_mlp(hn, lp["w_in"], lp["b_in"], lp["w_out"], lp["b_out"])
        return hh, (k, v)

    def _logits(self, params, h):
        cfg = self.cfg
        logits = jnp.einsum("bsd,vd->bsv", h, params["unembed"])
        if cfg.padded_vocab != cfg.vocab:
            pad = cfg.padded_vocab - cfg.vocab
            neg = jnp.full((*logits.shape[:-1], pad), -1e9, logits.dtype)
            logits = jnp.concatenate([logits[..., : cfg.vocab], neg], axis=-1)
        return logits

    def _forward_train(self, params, batch):
        cfg = self.cfg
        enc_out = self.encode(params, batch["src_frames"])
        tokens = batch["tokens"]
        B, St = tokens.shape
        h = params["embed"][tokens].astype(cfg.dtype)
        qpos = jnp.broadcast_to(jnp.arange(St, dtype=jnp.int32), (B, St))

        def body(carry, lp):
            hh = carry
            # project encoder K/V for this layer
            ck = jnp.einsum("bsd,df->bsf", enc_out, lp["ca_wk"]).reshape(
                B, enc_out.shape[1], cfg.n_kv_heads, cfg.dh)
            cv = jnp.einsum("bsd,df->bsf", enc_out, lp["ca_wv"]).reshape(
                B, enc_out.shape[1], cfg.n_kv_heads, cfg.dh)
            hh, _ = self._dec_block(hh, lp, self_k=None, self_v=None, self_pos=None,
                                    qpos=qpos, cross_k=ck, cross_v=cv, B=B, Sq=St)
            return hh, None

        h, _ = jax.lax.scan(maybe_remat(body, cfg.remat_policy), h, params["dec"])
        h = layer_norm(h, params["ln_f"], params["ln_fb"])
        return self._logits(params, h)

    def loss(self, params, batch):
        logits = self._forward_train(params, batch)
        labels = batch["labels"]
        return softmax_cross_entropy(logits, jnp.maximum(labels, 0), labels >= 0)

    # ----------------------------------------------------------------- serve
    def make_caches(self, batch: int, s_max: int, *, abstract: bool = False,
                    s_src: int = 0):
        cfg = self.cfg
        Ld, KVH, dh = cfg.dec_layers, cfg.n_kv_heads, cfg.dh
        s_src = s_src or max(s_max // 8, 1)
        shapes = dict(
            self_k=((Ld, batch, s_max, KVH, dh), cfg.dtype),
            self_v=((Ld, batch, s_max, KVH, dh), cfg.dtype),
            self_pos=((Ld, batch, s_max), jnp.int32),
            cross_k=((Ld, batch, s_src, KVH, dh), cfg.dtype),
            cross_v=((Ld, batch, s_src, KVH, dh), cfg.dtype),
            length=((batch,), jnp.int32),
        )
        if abstract:
            vals = {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in shapes.items()}
        else:
            vals = {k: jnp.zeros(s, d) for k, (s, d) in shapes.items()}
            vals["self_pos"] = jnp.full(shapes["self_pos"][0], -1, jnp.int32)
        return EncDecCache(**vals)

    def cache_axes(self):
        kv = ("layers", "batch", "seq", "kv_heads", "head_dim")
        return EncDecCache(
            self_k=kv, self_v=kv, self_pos=("layers", "batch", "seq"),
            cross_k=kv, cross_v=kv, length=("batch",),
        )

    def prefill(self, params, cache: EncDecCache, batch):
        """Encode source, project cross K/V, and prefill the target prefix."""
        cfg = self.cfg
        enc_out = self.encode(params, batch["src_frames"])
        tokens = batch["tokens"]
        B, Sq = tokens.shape
        Ss = enc_out.shape[1]
        h = params["embed"][tokens].astype(cfg.dtype)
        start = cache.length
        qpos = start[:, None] + jnp.arange(Sq, dtype=jnp.int32)[None, :]

        def body(carry, xs):
            hh = carry
            lp, sk, sv, sp = xs
            ck = jnp.einsum("bsd,df->bsf", enc_out, lp["ca_wk"]).reshape(
                B, Ss, cfg.n_kv_heads, cfg.dh)
            cv = jnp.einsum("bsd,df->bsf", enc_out, lp["ca_wv"]).reshape(
                B, Ss, cfg.n_kv_heads, cfg.dh)
            # write self K/V
            hn = layer_norm(hh, lp["sa_ln"], lp["sa_lnb"])
            q, k, v = self._qkv(hn, lp["sa_wq"], lp["sa_wk"], lp["sa_wv"])
            q = apply_rope(q, qpos, self.inv_freq, self.rot)
            k = apply_rope(k, qpos, self.inv_freq, self.rot)
            sk, sv = kv_cache_layer_update(sk, sv, k, v, start)
            sp = kv_cache_slot_positions(sp, qpos, start)
            o = attend(q, sk, sv, impl=cfg.attention_impl, causal=True,
                       q_positions=qpos, kv_positions=sp, kv_valid=sp >= 0)
            hh = hh + jnp.einsum("bsf,fd->bsd", o.reshape(B, Sq, -1), lp["sa_wo"])
            hn = layer_norm(hh, lp["ca_ln"], lp["ca_lnb"])
            qc = jnp.einsum("bsd,df->bsf", hn, lp["ca_wq"]).reshape(
                B, Sq, cfg.n_heads, cfg.dh)
            oc = attend(qc, ck, cv, impl=cfg.attention_impl, causal=False)
            hh = hh + jnp.einsum("bsf,fd->bsd", oc.reshape(B, Sq, -1), lp["ca_wo"])
            hn = layer_norm(hh, lp["ln_m"], lp["ln_mb"])
            hh = hh + gelu_mlp(hn, lp["w_in"], lp["b_in"], lp["w_out"], lp["b_out"])
            return hh, (sk, sv, sp, ck, cv)

        h, (sk, sv, sp, ck, cv) = jax.lax.scan(
            body, h, (params["dec"], cache.self_k, cache.self_v, cache.self_pos))
        h = layer_norm(h[:, -1:], params["ln_f"], params["ln_fb"])
        new = EncDecCache(self_k=sk, self_v=sv, self_pos=sp, cross_k=ck, cross_v=cv,
                          length=start + Sq)
        return self._logits(params, h)[..., : cfg.vocab], new

    def decode_step(self, params, cache: EncDecCache, tokens):
        cfg = self.cfg
        B, Sq = tokens.shape
        h = params["embed"][tokens].astype(cfg.dtype)
        start = cache.length
        qpos = start[:, None] + jnp.arange(Sq, dtype=jnp.int32)[None, :]

        def body(carry, xs):
            hh = carry
            lp, sk, sv, sp, ck, cv = xs
            hn = layer_norm(hh, lp["sa_ln"], lp["sa_lnb"])
            q, k, v = self._qkv(hn, lp["sa_wq"], lp["sa_wk"], lp["sa_wv"])
            q = apply_rope(q, qpos, self.inv_freq, self.rot)
            k = apply_rope(k, qpos, self.inv_freq, self.rot)
            sk, sv = kv_cache_layer_update(sk, sv, k, v, start)
            sp = kv_cache_slot_positions(sp, qpos, start)
            o = attend(q, sk, sv, impl=cfg.attention_impl, causal=True,
                       q_positions=qpos, kv_positions=sp, kv_valid=sp >= 0)
            hh = hh + jnp.einsum("bsf,fd->bsd", o.reshape(B, Sq, -1), lp["sa_wo"])
            hn = layer_norm(hh, lp["ca_ln"], lp["ca_lnb"])
            qc = jnp.einsum("bsd,df->bsf", hn, lp["ca_wq"]).reshape(
                B, Sq, cfg.n_heads, cfg.dh)
            oc = attend(qc, ck, cv, impl=cfg.attention_impl, causal=False)
            hh = hh + jnp.einsum("bsf,fd->bsd", oc.reshape(B, Sq, -1), lp["ca_wo"])
            hn = layer_norm(hh, lp["ln_m"], lp["ln_mb"])
            hh = hh + gelu_mlp(hn, lp["w_in"], lp["b_in"], lp["w_out"], lp["b_out"])
            return hh, (sk, sv, sp)

        h, (sk, sv, sp) = jax.lax.scan(
            body, h, (params["dec"], cache.self_k, cache.self_v, cache.self_pos,
                      cache.cross_k, cache.cross_v))
        h = layer_norm(h[:, -1:], params["ln_f"], params["ln_fb"])
        new = cache._replace(self_k=sk, self_v=sv, self_pos=sp, length=start + Sq)
        return self._logits(params, h)[..., : cfg.vocab], new
