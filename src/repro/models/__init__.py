"""Model zoo: composable pure-JAX implementations of the assigned families."""
from .api import ModelConfig, build_model  # noqa: F401
