"""Model zoo public API: config dataclass + the Model protocol.

Every architecture family implements :class:`Model`:

  init(key)                      -> (params, logical_axes_tree)
  loss(params, batch)            -> scalar fp32 mean CE
  prefill(params, caches, batch) -> (last_logits, caches)
  decode_step(params, caches, tokens) -> (logits, caches)
  make_caches(batch, s_max, abstract=...) -> cache pytree (or None)

``batch`` is a dict of arrays (see each family's docstring);
``abstract`` paths build ShapeDtypeStructs only (dry-run: no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp

__all__ = ["ModelConfig", "build_model"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_fraction: float = 1.0
    rope_base: float = 10000.0
    norm: str = "rms"  # rms | layer
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_ep: bool = True  # expert-parallel all-to-all path when a mesh is present
    # --- hybrid / recurrent ---
    block_pattern: tuple[str, ...] = ()  # e.g. ("rec", "rec", "attn")
    pattern_tail: tuple[str, ...] = ()  # trailing layers not covered by the pattern
    window: int = 0  # local attention window (0 = full)
    conv_width: int = 4  # temporal conv in recurrent blocks
    rnn_state_dim: int = 0  # RG-LRU recurrent width (0 -> d_model)
    # --- xlstm ---
    slstm_period: int = 0  # one sLSTM block per this many layers (0 = all mLSTM)
    mlstm_proj_factor: float = 2.0
    # --- enc-dec ---
    enc_layers: int = 0
    dec_layers: int = 0
    # --- modality frontends (STUBS: input_specs provides embeddings) ---
    n_prefix_tokens: int = 0  # vlm: vision patch embeddings prepended
    frontend: str = ""  # "vision" | "audio" | ""
    # --- execution ---
    attention_impl: str = "xla"  # "xla" | "pallas"
    vocab_pad_to: int = 0  # pad embedding rows for clean TP (logits masked)
    scan_layers: bool = True
    remat_policy: str = "none"  # "none" | "full" | "dots" (per-layer activation ckpt)
    dtype: Any = jnp.bfloat16

    @property
    def dh(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        return max(self.vocab, self.vocab_pad_to)

    @property
    def full_attention(self) -> bool:
        """True when every token attends over the entire unbounded context."""
        if self.family in ("ssm",):
            return False
        if self.family == "hybrid":
            return False  # bounded local window + recurrent state
        return True


def build_model(cfg: ModelConfig):
    """Instantiate the family implementation for a config."""
    if cfg.family in ("dense", "vlm"):
        from .dense import DenseLM

        return DenseLM(cfg)
    if cfg.family == "moe":
        from .moe import MoELM

        return MoELM(cfg)
    if cfg.family == "ssm":
        from .xlstm import XLSTMLM

        return XLSTMLM(cfg)
    if cfg.family == "hybrid":
        from .rglru import GriffinLM

        return GriffinLM(cfg)
    if cfg.family == "audio":
        from .encdec import EncDecLM

        return EncDecLM(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")
