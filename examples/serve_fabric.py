"""Fabric-manager service demo: stream trace-derived coflow arrivals through
the admission queue, schedule them incrementally against committed circuits,
and emit validated per-tick circuit programs.

  PYTHONPATH=src python examples/serve_fabric.py

Pure control-plane numpy — no accelerator needed. The same loop at load is
``benchmarks/bench_service.py``; the one-shot cached plane is what
``repro.comm.planner.plan_circuits_service`` uses to replan a training
step's collectives every iteration for free.
"""
import numpy as np

from repro.core import (
    arrival_stream,
    run_fast_online,
    sample_online_instance,
    synth_fb_trace,
)
from repro.service import FabricConfig, FabricManager

N, M, TICKS = 16, 80, 12
RATES, DELTA = (10.0, 20.0, 30.0), 8.0

trace = synth_fb_trace(526, seed=2026)
offline = sample_online_instance(trace, N=N, M=M, rates=RATES, delta=DELTA,
                                 span=0.0, seed=7)
makespan = float(run_fast_online(offline, "ours").ccts.max())
oinst = sample_online_instance(trace, N=N, M=M, rates=RATES, delta=DELTA,
                               span=makespan, seed=7)

mgr = FabricManager(FabricConfig(rates=RATES, delta=DELTA, N=N,
                                 validate_every_tick=True))
arrivals = list(arrival_stream(oinst))
nxt = 0
print(f"serving N={N} M={M} stream over {TICKS} ticks "
      f"(arrival span = offline makespan = {makespan:.0f})")
for T in np.linspace(makespan / TICKS, makespan, TICKS):
    while nxt < len(arrivals) and arrivals[nxt][1] <= T:
        mgr.submit(*arrivals[nxt])
        nxt += 1
    rep = mgr.tick(float(T))
    print(f"  t={rep.t_now:7.1f}  admitted {rep.admitted:3d}  "
          f"committed {rep.committed_flows:5d} circuits  "
          f"finalized {rep.finalized:3d}  backlog {rep.pending_flows:5d}")
rep = mgr.flush()
print(f"  flush     committed {rep.committed_flows:5d} circuits  "
      f"finalized {rep.finalized:3d}")

program = mgr.program()
program.validate()
summary = mgr.summary()
print(f"\nmerged program: {program.n_segments} circuit segments, "
      f"makespan {program.makespan:.1f} (validated)")
print(f"decision latency p50/p99: {summary['decision_latency_p50_s']*1e3:.1f}/"
      f"{summary['decision_latency_p99_s']*1e3:.1f} ms; "
      f"throughput {summary['coflows_per_s']:.0f} coflows/s")
events = list(program.events())
print("first switch actions:")
for ev in events[:6]:
    print(f"  t={ev.t:8.2f} core {ev.core}  {ev.kind:9s} "
          f"{ev.ingress:2d} -> {ev.egress:2d}  (coflow {ev.cid})")
