"""Fault-recovery demo: kill an OCS core mid-stream and watch the fabric
manager abort in-flight circuits, re-queue their demand over the surviving
cores, and keep every emitted program referee-valid.

  PYTHONPATH=src python examples/fault_recovery.py

Pure control-plane numpy. The same machinery at load (recovery latency,
degraded-vs-healthy weighted CCT) is ``benchmarks/bench_fault.py``; the
elastic-training wiring (a DeviceLoss shrinking mesh + circuit plane in one
story) is ``distributed.fault.ElasticTrainer(fabric=..., mesh_cores=...)``.
"""
import numpy as np

from repro.core import CoreDown, CoreUp, run_fast_online, \
    sample_online_instance, synth_fb_trace
from repro.service import FabricConfig, FabricManager

N, M, TICKS = 16, 80, 12
RATES, DELTA = (10.0, 20.0, 30.0), 8.0

trace = synth_fb_trace(526, seed=2026)
offline = sample_online_instance(trace, N=N, M=M, rates=RATES, delta=DELTA,
                                 span=0.0, seed=7)
makespan = float(run_fast_online(offline, "ours").ccts.max())
oinst = sample_online_instance(trace, N=N, M=M, rates=RATES, delta=DELTA,
                               span=makespan, seed=7)

mgr = FabricManager(FabricConfig(rates=RATES, delta=DELTA, N=N,
                                 validate_every_tick=True))
order = np.argsort(oinst.releases, kind="stable")
rel = oinst.releases
ticks = np.linspace(makespan / TICKS, makespan, TICKS)
fail_tick = TICKS // 2
nxt = 0
print(f"serving N={N} M={M} stream over {TICKS} ticks; "
      f"core 2 dies after tick {fail_tick}, returns after tick "
      f"{fail_tick + 3}")
for i, T in enumerate(ticks):
    while nxt < order.size and rel[order[nxt]] <= T:
        m = int(order[nxt])
        mgr.submit(oinst.inst.coflows[m], float(rel[m]))
        nxt += 1
    rep = mgr.tick(float(T))
    print(f"  t={rep.t_now:7.1f}  admitted {rep.admitted:3d}  "
          f"committed {rep.committed_flows:4d}  finalized {rep.finalized:3d}"
          f"  backlog {rep.pending_flows:4d}  cores up "
          f"{mgr.summary()['cores_up']}")
    if i == fail_tick:
        fault = mgr.report_fault(CoreDown(t=float(T) + 1.0, core=2))
        print(f"  !! core 2 DOWN at t={float(T)+1.0:.1f}: "
              f"{fault.aborted} in-flight circuits aborted, "
              f"{fault.requeued} flows re-queued, "
              f"{fault.reassigned_pending} tentative flows reassigned, "
              f"{len(fault.unfinalized)} final CCTs retracted, "
              f"{fault.cache_purged} cache entries purged")
        for ev in fault.teardowns[:3]:
            print(f"     teardown core {ev.core}  {ev.ingress:2d} -> "
                  f"{ev.egress:2d}  (coflow {ev.cid})")
    if i == fail_tick + 3:
        mgr.report_fault(CoreUp(t=float(T) + 1.0, core=2))
        print(f"  !! core 2 UP at t={float(T)+1.0:.1f}")
rep = mgr.flush()
print(f"  flush     committed {rep.committed_flows:4d}  "
      f"finalized {rep.finalized:3d}")

program = mgr.program()  # program of record: aborted segments excluded
program.validate()
s = mgr.summary()
print(f"\nprogram of record: {program.n_segments} circuit segments, "
      f"makespan {program.makespan:.1f} (referee-validated)")
print(f"all {s['coflows_finalized']}/{M} coflows finalized exactly once; "
      f"{s['circuits_aborted']} circuits aborted, "
      f"{s['flows_requeued']} flows re-served after the fault")
assert s["coflows_finalized"] == M
