"""Batched serving example: prefill a batch of prompts, then decode with the
sharded KV cache engine — one round of continuous batching (a finished row is
replaced by a fresh request between decode steps).

  PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models.api import build_model

ARCH = "qwen1.5-0.5b"
B, PROMPT, GEN = 4, 32, 24

cfg = get_arch(ARCH).smoke
model = build_model(cfg)
params, _ = model.init(jax.random.key(0))

rng = np.random.default_rng(0)
prompts = jnp.asarray(rng.integers(1, cfg.vocab, (B, PROMPT)), jnp.int32)

cache = model.make_caches(B, PROMPT + GEN + 8)
prefill = jax.jit(model.prefill)
decode = jax.jit(model.decode_step)

t0 = time.time()
logits, cache = prefill(params, cache, {"tokens": prompts})
t_prefill = time.time() - t0

out = []
t0 = time.time()
for step in range(GEN):
    nxt = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    out.append(np.asarray(nxt)[:, 0])
    logits, cache = decode(params, cache, nxt)
t_decode = time.time() - t0

gen = np.stack(out, axis=1)
print(f"arch={ARCH} (reduced) batch={B} prompt={PROMPT} gen={GEN}")
print(f"prefill: {t_prefill*1e3:.1f} ms total "
      f"({B*PROMPT/t_prefill:.0f} tok/s)")
print(f"decode : {t_decode/GEN*1e3:.1f} ms/step "
      f"({B*GEN/t_decode:.0f} tok/s)")
for b in range(B):
    print(f"  request {b}: {gen[b].tolist()}")
