"""Fabric observatory demo: trace a streamed service run, then inspect it.

  PYTHONPATH=src python examples/observe_fabric.py [OUT_DIR]

Streams a trace-derived arrival sequence through ``FabricManager`` with a
``repro.obs`` tracer attached (including a mid-stream core failure, so the
``fault/recover`` span shows up), writes the span trace as JSONL plus a
Perfetto-loadable Chrome trace, and prints the same per-phase wall
breakdown ``python -m repro.obs summarize`` would. CI's fast lane runs
this script and schema-validates + archives the artifacts it writes.

Inspect interactively afterwards:

  python -m repro.obs summarize OUT_DIR/trace.jsonl
  python -m repro.obs export-chrome OUT_DIR/trace.jsonl -o chrome.json
  # then load chrome.json at https://ui.perfetto.dev
"""
import json
import sys
from pathlib import Path

import numpy as np

from repro.core import CoreDown, run_fast_online, sample_online_instance, synth_fb_trace
from repro.obs import Tracer
from repro.obs.cli import summarize, validate_records
from repro.service import FabricConfig, FabricManager

N, M, TICKS = 16, 60, 10
RATES, DELTA = (10.0, 20.0, 30.0), 8.0

out_dir = Path(sys.argv[1]) if len(sys.argv) > 1 else Path("obs_out")
out_dir.mkdir(parents=True, exist_ok=True)

trace = synth_fb_trace(526, seed=2026)
offline = sample_online_instance(trace, N=N, M=M, rates=RATES, delta=DELTA,
                                 span=0.0, seed=7)
makespan = float(run_fast_online(offline, "ours").ccts.max())
oinst = sample_online_instance(trace, N=N, M=M, rates=RATES, delta=DELTA,
                               span=makespan, seed=7)

tracer = Tracer(out_dir / "trace.jsonl")
mgr = FabricManager(FabricConfig(rates=RATES, delta=DELTA, N=N,
                                 validate_every_tick=True), tracer=tracer)

order = np.argsort(oinst.releases, kind="stable")
rel = oinst.releases
nxt = 0
ticks = np.linspace(makespan / TICKS, makespan, TICKS)
print(f"tracing N={N} M={M} stream over {TICKS} ticks "
      f"-> {out_dir / 'trace.jsonl'}")
for i, T in enumerate(ticks):
    while nxt < order.size and rel[order[nxt]] <= T:
        m = int(order[nxt])
        mgr.submit(oinst.inst.coflows[m], float(rel[m]))
        nxt += 1
    mgr.tick(float(T))
    if i == TICKS // 2:  # mid-stream churn: a core fails and recovers
        rep = mgr.report_fault(CoreDown(t=float(T), core=1))
        print(f"  t={T:7.1f}  core 1 down: aborted {rep.aborted}, "
              f"requeued {rep.requeued}")
mgr.flush()
tracer.close()

problems = validate_records(tracer.records)
assert not problems, problems
assert tracer.open_spans == 0

chrome = out_dir / "chrome_trace.json"
with open(chrome, "w", encoding="utf-8") as fh:
    json.dump(tracer.to_chrome_trace(), fh)

summ = summarize(tracer.records)
print(f"\n{len(tracer.records)} records, schema OK; phase breakdown:")
for name in sorted(summ["phases"], key=lambda n: -summ["phases"][n]["total_s"]):
    st = summ["phases"][name]
    print(f"  {name:<20} x{int(st['count']):<5} total {st['total_s']:.4f}s")
print(f"events: {summ['events'] or '(none)'}")
print(f"\nwrote {chrome} — load it at https://ui.perfetto.dev")
print(f"summary: {json.dumps(mgr.summary(), default=float)[:160]}...")
