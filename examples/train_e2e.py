"""End-to-end driver: train a ~100M-parameter llama-family model for a few
hundred steps on the synthetic corpus, with checkpointing and straggler
watchdog — the deliverable-(b) training example.

  PYTHONPATH=src python examples/train_e2e.py [--steps 300] [--small]

(--small trims to ~20M params / 100 steps for quick CPU runs; the default
~100M config is the honest deliverable and takes a while on CPU.)
"""
import argparse
import dataclasses
import json

import numpy as np

from repro.configs import get_arch
from repro.launch.train import train_loop
from repro.models.common import param_count
from repro.train.optimizer import OptimizerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e_ckpt")
    args = ap.parse_args()

    base = get_arch("tinyllama-1.1b").config
    if args.small:
        cfg = dataclasses.replace(base, n_layers=6, d_model=256, n_heads=8,
                                  n_kv_heads=4, d_ff=768, vocab=8192)
        gb, sl = 4, 256
        steps = min(args.steps, 100)
    else:
        # ~100M params: 12L x 640d, 32k vocab
        cfg = dataclasses.replace(base, n_layers=12, d_model=640, n_heads=10,
                                  n_kv_heads=5, d_ff=1792, vocab=32000)
        gb, sl = 8, 512
        steps = args.steps

    from repro.models.api import build_model
    import jax
    n_params = param_count(build_model(cfg).init(jax.random.key(0))[0])
    print(f"model: {cfg.n_layers}L d={cfg.d_model} vocab={cfg.vocab} "
          f"-> {n_params/1e6:.1f}M params; {steps} steps of "
          f"{gb}x{sl} tokens")

    run = train_loop(
        cfg, steps=steps, global_batch=gb, seq_len=sl,
        opt_cfg=OptimizerConfig(lr=6e-4, total_steps=steps,
                                warmup_steps=max(steps // 20, 5)),
        ckpt_dir=args.ckpt_dir, ckpt_every=50, log_every=10)

    losses = [h["loss"] for h in run.history]
    print(json.dumps({
        "params_m": round(n_params / 1e6, 1),
        "first10_loss": float(np.mean(losses[:10])),
        "last10_loss": float(np.mean(losses[-10:])),
        "steps": run.steps_done,
    }))


if __name__ == "__main__":
    main()
