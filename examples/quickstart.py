"""Quickstart: the paper's scheduler in 30 lines, then the framework in 30.

Part 1 schedules a hand-built multi-coflow instance on a 3-core OCS network
with Algorithm 1 and checks the paper's guarantees. Part 2 trains a tiny
LM for a few steps and serves one batched generation.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

# --- Part 1: the paper -----------------------------------------------------
from repro.core import (
    Coflow, Instance, run, validate,
    check_lemma1, check_lemma2, check_theorem1,
)

rng = np.random.default_rng(0)
coflows = []
for cid in range(8):
    D = rng.exponential(20, (6, 6)) * (rng.random((6, 6)) < 0.4)
    coflows.append(Coflow(cid=cid, demand=D, weight=float(rng.integers(1, 5))))
inst = Instance(coflows=tuple(coflows), rates=np.array([10., 20., 30.]), delta=2.0)

schedule = run(inst, "ours")          # Algorithm 1, all three phases
validate(schedule)                    # port exclusivity / timing / conservation
check_lemma1(schedule)                # T_m >= delta + rho_m / R
check_lemma2(schedule)                # assignment-phase prefix bound
check_theorem1(schedule)              # 2 M (wmax/wmin) psi bound
print(f"[paper] weighted CCT = {schedule.total_weighted_cct:.2f}, "
      f"makespan = {schedule.ccts.max():.2f}")
for alg in ("rho-assign", "rand-assign", "sunflow-core", "rand-sunflow"):
    s = run(inst, alg)
    validate(s)
    print(f"[paper] {alg:13s} normalized wCCT = "
          f"{s.total_weighted_cct / schedule.total_weighted_cct:.2f}x")

# --- Part 2: the framework ---------------------------------------------------
import jax
from repro.configs import get_arch
from repro.launch.train import train_loop
from repro.train.optimizer import OptimizerConfig

cfg = get_arch("tinyllama-1.1b").smoke
run_out = train_loop(cfg, steps=30, global_batch=4, seq_len=128,
                     opt_cfg=OptimizerConfig(lr=1e-3, total_steps=30,
                                             warmup_steps=3), log_every=10)
print(f"[framework] loss {run_out.history[0]['loss']:.3f} -> "
      f"{run_out.history[-1]['loss']:.3f} over 30 steps")

model, params = run_out.model, run_out.params
cache = model.make_caches(2, 64)
prompt = jax.numpy.zeros((2, 8), jax.numpy.int32)
logits, cache = jax.jit(model.prefill)(params, cache, {"tokens": prompt})
toks = []
for _ in range(8):
    nxt = jax.numpy.argmax(logits[:, -1], -1)[:, None].astype(jax.numpy.int32)
    toks.append(np.asarray(nxt)[:, 0])
    logits, cache = jax.jit(model.decode_step)(params, cache, nxt)
print(f"[framework] generated tokens: {np.stack(toks, 1).tolist()}")
