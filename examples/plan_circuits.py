"""The paper's technique as a first-class framework feature: compile a
training step, extract its cross-block collective traffic as coflows over
the multi-core OCS pod interconnect, and plan the circuit schedule with
Algorithm 1 — printing the circuit program a Jupiter-style fabric manager
would install.

  PYTHONPATH=src python examples/plan_circuits.py [--arch phi3.5-moe-42b-a6.6b]

Runs on a small stand-in mesh (8 devices) so it finishes in seconds; the
production path (512 devices) is benchmarks/comm_planner.py.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse

import jax

from repro.analysis.hlo import analyze_hlo
from repro.comm import BlockMap, OCSFabric, plan_circuits, step_coflows
from repro.distributed.sharding import TRAIN_RULES, batch_spec, plan_tree
from repro.models.api import ModelConfig, build_model
from repro.models.common import activation_sharding
from repro.train.optimizer import OptimizerConfig, abstract_opt_state
from repro.train.step import build_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--experts", type=int, default=8)
    args = ap.parse_args()

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = ModelConfig(name="demo-moe", family="moe", n_layers=4, d_model=256,
                      n_heads=8, n_kv_heads=4, d_ff=512, vocab=1024,
                      n_experts=args.experts, top_k=2)
    model = build_model(cfg)
    params, axes = model.init(None)
    batch = {"tokens": jax.ShapeDtypeStruct((16, 256), jax.numpy.int32),
             "labels": jax.ShapeDtypeStruct((16, 256), jax.numpy.int32)}
    p_sh = plan_tree(mesh, params, axes, TRAIN_RULES)
    o_sh = {"master": p_sh, "m": p_sh, "v": p_sh,
            "step": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())}
    b_sh = {k: batch_spec(mesh, v.ndim, v.shape[0]) for k, v in batch.items()}
    rep = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    msh = {k: rep for k in ("grad_norm", "lr", "param_norm", "loss")}
    step = build_train_step(model, OptimizerConfig())
    with activation_sharding(mesh, TRAIN_RULES):
        compiled = jax.jit(
            step, in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, msh)).lower(
            params, abstract_opt_state(params), batch).compile()

    analysis = analyze_hlo(compiled.as_text(), total_devices=8)
    print(f"collectives in the compiled step: {analysis.collective_counts()}")

    bmap = BlockMap.from_mesh_shape(dict(mesh.shape), ("pod", "data"))
    coflows = step_coflows(analysis, bmap)
    print(f"-> {len(coflows)} coflows over {bmap.n_blocks} aggregation blocks, "
          f"{sum(c.total_bytes for c in coflows)/1e6:.1f} MB inter-block")

    fabric = OCSFabric(rates=(25e9, 50e9), delta=1e-3)
    reports = plan_circuits(coflows, fabric)
    base = reports["ours"].weighted_cct
    print(f"\n{'algorithm':14s} {'wCCT':>10s} {'makespan':>10s} {'norm':>6s}")
    for alg, r in reports.items():
        print(f"{alg:14s} {r.weighted_cct:9.4f}s {r.makespan:9.4f}s "
              f"{r.weighted_cct/base:5.2f}x")

    # print the first few circuit establishments of OURS — the program the
    # fabric manager would install
    print("\nfirst 10 circuit establishments (OURS):")
    flows = sorted(reports["ours"].schedule.flows, key=lambda f: f.t_establish)
    for f in flows[:10]:
        print(f"  t={f.t_establish*1e3:7.2f}ms core={f.core} "
              f"block{f.i:2d} -> block{f.j:2d}  "
              f"{f.size/1e6:8.2f} MB  (coflow {f.cid})")


if __name__ == "__main__":
    main()
